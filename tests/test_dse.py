"""DSE coverage: predict_cost monotonicity, pareto_front semantics, the
measured-feedback CostCorrection, and explore-with-measurement smokes
(single-op and chain)."""
import dataclasses

import pytest

from repro.cfd import operators
from repro.memory import channels, dse


BASE = dict(
    policy="float32", batch_elements=1024, flops_per_element=20_000,
    host_bytes=8 << 20, hbm_bytes=8 << 20, channels_used=4,
    prefetch_depth=1, cu_count=1,
)


def _cost(**over):
    kw = {**BASE, **over}
    return dse.predict_cost(channels.ALVEO_U280, **kw)


# ---------------------------------------------------------------------------
# predict_cost monotonicity (the model's core guarantee)
# ---------------------------------------------------------------------------


def test_predict_cost_monotone_in_channels():
    """More assigned pseudo-channels never predicts slower (the paper's
    point: unmapped channels are wasted bandwidth)."""
    t = channels.ALVEO_U280
    prev = None
    for ch in range(1, t.n_channels + 1):
        c = _cost(channels_used=ch)
        if prev is not None:
            assert c.t_hbm <= prev.t_hbm * (1 + 1e-12)
            assert c.t_pipelined <= prev.t_pipelined * (1 + 1e-12)
            assert c.t_serial <= prev.t_serial * (1 + 1e-12)
        prev = c
    # beyond the physical channel count, bandwidth stops improving
    assert _cost(channels_used=t.n_channels + 8).t_hbm == pytest.approx(
        _cost(channels_used=t.n_channels).t_hbm
    )


def test_predict_cost_monotone_in_prefetch_depth():
    """Deeper K never predicts slower under the steady-state model (no
    n_batches => no pipeline-fill term)."""
    prev = None
    for k in (0, 1, 2, 4, 8):
        c = _cost(prefetch_depth=k)
        if prev is not None:
            assert c.t_pipelined <= prev.t_pipelined * (1 + 1e-12)
        prev = c


def test_predict_cost_fill_term_bounded():
    """With a finite batch count the K-deep fill cost is charged, but
    never exceeds the available batches (K >= n_batches saturates)."""
    deep = _cost(prefetch_depth=16, n_batches=4)
    deeper = _cost(prefetch_depth=64, n_batches=4)
    assert deep.t_pipelined == pytest.approx(deeper.t_pipelined)
    nofill = _cost(prefetch_depth=1)
    assert _cost(prefetch_depth=1, n_batches=4).t_pipelined >= (
        nofill.t_pipelined
    )


# ---------------------------------------------------------------------------
# pareto_front
# ---------------------------------------------------------------------------


def test_pareto_front_on_explored_candidates():
    cands = dse.explore(7, target=channels.ALVEO_U280, n_eq=1 << 14)
    front = dse.pareto_front(cands)
    assert front
    feas = [c for c in cands if c.plan.feasible]
    # the top-ranked feasible candidate is never dominated
    assert any(f is feas[0] for f in front)
    # no member dominates another
    for a in front:
        for b in front:
            if a is b:
                continue
            dominates = (
                a.predicted_s_per_element <= b.predicted_s_per_element
                and a.plan.resident_bytes <= b.plan.resident_bytes
                and (a.predicted_s_per_element < b.predicted_s_per_element
                     or a.plan.resident_bytes < b.plan.resident_bytes)
            )
            assert not dominates
    # every non-front feasible candidate is dominated by some front member
    front_ids = {id(c) for c in front}
    for c in feas:
        if id(c) in front_ids:
            continue
        assert any(
            f.predicted_s_per_element <= c.predicted_s_per_element
            and f.plan.resident_bytes <= c.plan.resident_bytes
            for f in front
        )


def test_pareto_front_excludes_infeasible():
    cands = dse.explore(
        11,
        target=channels.ALVEO_U280.with_(hbm_bytes=2 ** 26, n_channels=4),
        n_eq=1 << 16,
    )
    front = dse.pareto_front(cands)
    assert all(c.plan.feasible for c in front)


# ---------------------------------------------------------------------------
# measured-feedback correction
# ---------------------------------------------------------------------------


def _measured_cand(pred, meas, feasible=True):
    plan = dse.make_plan(5, target=channels.ALVEO_U280, batch_elements=64)
    if not feasible:
        plan = dataclasses.replace(plan, feasible=False)
    return dse.Candidate(
        plan=plan, predicted_s_per_element=pred,
        measured_s_per_element=meas,
    )


def test_fit_correction_geometric_mean():
    cands = [
        _measured_cand(1e-6, 2e-6),   # ratio 2
        _measured_cand(1e-6, 8e-6),   # ratio 8
        _measured_cand(1e-6, None),   # unmeasured: ignored
    ]
    corr = dse.fit_correction(cands)
    assert corr.n_samples == 2
    assert corr.factor == pytest.approx(4.0)  # sqrt(2 * 8)
    assert corr.corrected(1e-6) == pytest.approx(4e-6)


def test_fit_correction_identity_without_measurements():
    corr = dse.fit_correction([_measured_cand(1e-6, None)])
    assert corr.factor == 1.0 and corr.n_samples == 0
    assert corr.corrected(3.0) == 3.0


def _cand_with_terms(pred, meas, t_compute, t_hbm, t_host):
    plan = dse.make_plan(5, target=channels.ALVEO_U280, batch_elements=64)
    cost = dataclasses.replace(
        plan.cost, t_compute=t_compute, t_hbm=t_hbm, t_host=t_host
    )
    return dse.Candidate(
        plan=dataclasses.replace(plan, cost=cost),
        predicted_s_per_element=pred, measured_s_per_element=meas,
    )


def test_fit_correction_learns_per_term_factors():
    """Ratios are attributed to the measured run's bottleneck term:
    host-bound ladders calibrate the host factor, compute-bound ladders
    the compute factor; unobserved terms fall back to the overall
    geometric mean."""
    host = _cand_with_terms(1e-6, 2e-6, 0.1, 0.2, 1.0)   # ratio 2
    comp = _cand_with_terms(1e-6, 8e-6, 1.0, 0.2, 0.1)   # ratio 8
    corr = dse.fit_correction([host, comp])
    assert corr.n_samples == 2
    assert corr.host_factor == pytest.approx(2.0)
    assert corr.compute_factor == pytest.approx(8.0)
    assert corr.hbm_factor is None
    assert corr.factor == pytest.approx(4.0)
    assert corr.factor_for("host-link") == pytest.approx(2.0)
    assert corr.factor_for("compute") == pytest.approx(8.0)
    assert corr.factor_for("hbm") == pytest.approx(4.0)    # fallback
    assert corr.factor_for(None) == pytest.approx(4.0)
    assert corr.corrected(1e-6, "compute") == pytest.approx(8e-6)
    # apply_correction scales each candidate by its own bottleneck term
    fast = dse.Candidate(plan=host.plan, predicted_s_per_element=2e-6)
    dse.apply_correction([host, comp, fast], corr)
    assert fast.corrected_s_per_element == pytest.approx(4e-6)
    assert comp.corrected_s_per_element == pytest.approx(8e-6)


def test_calibrate_requires_measurement():
    with pytest.raises(ValueError, match="measure_top"):
        dse.explore(5, target=channels.CPU_HOST, n_eq=64, calibrate=True)


def test_apply_correction_reranks():
    slow = _measured_cand(1e-6, 5e-6)       # measured: actually slow
    fast = _measured_cand(2e-6, None)       # predicted-only
    ranked = dse.apply_correction([slow, fast], dse.fit_correction([slow]))
    # correction factor 5: fast's corrected prediction = 1e-5 > slow's
    # measured 5e-6, so the measured candidate wins the re-rank
    assert ranked[0] is slow
    assert fast.corrected_s_per_element == pytest.approx(1e-5)


@pytest.mark.slow
def test_explore_calibrate_smoke():
    """Measure-then-calibrate on a tiny program: every candidate gains a
    corrected prediction and feasible candidates stay ranked first."""
    space = dse.DesignSpace(
        backends=("xla",), policies=("float32",), batch_divisors=(1, 2),
        prefetch_depths=(0, 1), cu_counts=(1,),
    )
    cands = dse.explore(
        5, target=channels.CPU_HOST, n_eq=128, space=space,
        measure_top=1, measure_batches=2, calibrate=True,
    )
    assert any(c.verified for c in cands)
    assert all(c.corrected_s_per_element is not None for c in cands)
    feas = [c.plan.feasible for c in cands]
    assert feas == sorted(feas, reverse=True)


# ---------------------------------------------------------------------------
# chain exploration
# ---------------------------------------------------------------------------


def test_explore_chain_ranked_and_pareto():
    chain = operators.build_cfd_chain(5)
    space = dse.ChainDesignSpace(
        backends=("xla", "staged"), batch_divisors=(1, 2),
        prefetch_depths=(0, 1),
    )
    cands = dse.explore_chain(
        chain, target=channels.ALVEO_U280, n_eq=1 << 14, space=space
    )
    # every (backends, E) point contributes at least the chain-wide
    # uniform (cu, depth) grid (8 combos x 2 E x 2 K) plus the joint
    # per-stage placement frontier, deduplicated
    assert len(cands) >= 32
    assert len(
        {(tuple(sp.backend for sp in c.plan.stages),
          c.plan.batch_elements,
          tuple(sp.prefetch_depth for sp in c.plan.stages),
          c.plan.cu_counts)
         for c in cands}
    ) == len(cands)
    feas = [c for c in cands if c.plan.feasible]
    assert feas
    pred = [c.predicted_s_per_element for c in feas]
    assert pred == sorted(pred)
    assert all(c.plan.feasible for c in cands[: len(feas)])
    # ChainPlan quacks enough like MemoryPlan for the same pareto code
    front = dse.pareto_front(cands)
    assert front and all(c.plan.feasible for c in front)
    # per-stage backends really vary across the sweep
    combos = {tuple(sp.backend for sp in c.plan.stages) for c in cands}
    assert len(combos) == 8
    # ... and so do the per-stage depth vectors (the joint search emits
    # non-uniform placements, not just the chain-wide sweep)
    depth_vecs = {
        tuple(sp.prefetch_depth for sp in c.plan.stages) for c in cands
    }
    assert any(len(set(v)) > 1 for v in depth_vecs)


def test_chain_cost_overlap_term():
    """The cross-batch overlap term: a pipelined chain is priced by its
    slowest stage plus amortized fill/drain, never worse than the
    back-to-back schedule, and n_batches=1 degenerates to it exactly."""
    from repro.memory import chain as mchain

    chain = operators.build_cfd_chain(5)
    piped = mchain.plan_chain(
        chain, target=channels.ALVEO_U280, batch_elements=256,
        prefetch_depth=1, n_eq=1 << 12,
    )
    flat = mchain.plan_chain(
        chain, target=channels.ALVEO_U280, batch_elements=256,
        prefetch_depth=(1, 0, 0), n_eq=1 << 12,
    )
    assert piped.cost.pipelined_stages and not flat.cost.pipelined_stages
    # the steady state is the slowest *contended* stage: on the default
    # single-device topology all three stages time-slice one device
    assert piped.cost.contention == (3, 3, 3)
    assert piped.cost.t_steady == max(piped.cost.stage_steady_times)
    assert piped.cost.t_steady >= max(
        max(c.t_host, c.t_compute, c.t_hbm) + c.t_overhead
        for c in piped.cost.stages
    )
    # a disjoint placement (one device per stage) removes the contention
    # and can only speed the steady state up
    from repro.memory.placement import DeviceTopology

    disjoint = mchain.plan_chain(
        chain, target=channels.ALVEO_U280, batch_elements=256,
        prefetch_depth=1, n_eq=1 << 12,
        topology=DeviceTopology.homogeneous(3),
    )
    assert disjoint.placement.contention == (1, 1, 1)
    assert disjoint.cost.t_steady <= piped.cost.t_steady * (1 + 1e-12)
    assert piped.cost.t_pipelined == pytest.approx(
        min(piped.cost.t_back_to_back,
            piped.cost.t_steady + piped.cost.t_fill)
    )
    assert piped.cost.t_pipelined <= flat.cost.t_pipelined * (1 + 1e-12)
    assert piped.cost.stage_overlap_speedup >= 1.0 - 1e-12
    assert flat.cost.t_pipelined == pytest.approx(flat.cost.t_back_to_back)
    # the correction hook: a chain's bottleneck is its bottleneck
    # stage's dominating term
    idx = piped.cost.bottleneck_stage
    assert piped.cost.bottleneck == piped.cost.stages[idx].bottleneck
    one = mchain.plan_chain(
        chain, target=channels.ALVEO_U280, batch_elements=256,
        prefetch_depth=1, n_eq=256,
    )
    assert one.cost.t_overlapped == pytest.approx(one.cost.t_back_to_back)


def test_explore_chain_calibrate_requires_measurement():
    chain = operators.build_cfd_chain(5)
    with pytest.raises(ValueError, match="measure_top"):
        dse.explore_chain(chain, target=channels.CPU_HOST, calibrate=True)


@pytest.mark.slow
def test_explore_chain_calibrate_smoke():
    """Measure-then-calibrate on the real chain driver: every candidate
    gains a corrected prediction, feasible candidates stay ranked
    first."""
    chain = operators.build_cfd_chain(5)
    space = dse.ChainDesignSpace(
        backends=("xla",), batch_divisors=(1,), prefetch_depths=(0, 1),
    )
    cands = dse.explore_chain(
        chain, target=channels.CPU_HOST, n_eq=64, space=space,
        measure_top=1, measure_batches=2, calibrate=True,
    )
    assert any(c.verified for c in cands)
    assert all(c.corrected_s_per_element is not None for c in cands)
    feas = [c.plan.feasible for c in cands]
    assert feas == sorted(feas, reverse=True)


@pytest.mark.slow
def test_explore_chain_measures_matching_candidates():
    """measure_top verifies the best candidates whose planned backends
    match how the chain was compiled, through the real run_chain."""
    chain = operators.build_cfd_chain(5)
    space = dse.ChainDesignSpace(
        backends=("xla",), batch_divisors=(1,), prefetch_depths=(0, 1),
    )
    cands = dse.explore_chain(
        chain, target=channels.CPU_HOST, n_eq=64, space=space,
        measure_top=1, measure_batches=2,
    )
    assert any(c.verified for c in cands)
    best = next(c for c in cands if c.verified)
    assert best.measured_s_per_element > 0
    # a plan whose backends differ from the compiled chain is refused
    staged_plan = dse.explore_chain(
        chain, target=channels.CPU_HOST, n_eq=64,
        space=dse.ChainDesignSpace(
            backends=("staged",), batch_divisors=(1,),
            prefetch_depths=(0,),
        ),
    )[0].plan
    assert dse.measure_chain_plan(chain, staged_plan) is None
