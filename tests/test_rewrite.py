"""Middle-end rewrites: factorization (the paper's key transform), CSE.

Includes hypothesis property tests: for random contraction-of-product
programs, the optimized program computes the same function at lower or
equal cost.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import dsl, emit, ir, rewrite
from repro.core.precision import F32
from repro.cfd import reference


@pytest.mark.parametrize("p", [3, 5, 7, 11, 13])
def test_factorized_flops_match_paper_model(p):
    """Paper Eq. (2): the factorized Inverse Helmholtz costs exactly
    (12p+1)p^3 flops."""
    prog = rewrite.optimize(dsl.inverse_helmholtz_program(p))
    assert prog.total_flops() == (12 * p + 1) * p ** 3


@pytest.mark.parametrize("p", [3, 5, 7])
def test_factorization_preserves_semantics(p, rng):
    prog = dsl.inverse_helmholtz_program(p)
    opt = rewrite.optimize(prog)
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (p, p, p)).astype(np.float32)
    env = {"S": S, "D": D, "u": u}
    naive = emit.compile_program(prog, policy=F32).element_fn(env)["v"]
    fact = emit.compile_program(opt, policy=F32).element_fn(env)["v"]
    want = reference.inverse_helmholtz(
        S.astype(np.float64), D.astype(np.float64), u.astype(np.float64)
    )
    np.testing.assert_allclose(np.asarray(fact), want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(naive), want, rtol=2e-4, atol=2e-4)


def test_factorization_reduces_flops_dramatically():
    prog = dsl.inverse_helmholtz_program(11)
    opt = rewrite.optimize(prog)
    assert prog.total_flops() / opt.total_flops() > 1000


def test_cse_shares_repeated_inputs():
    prog = rewrite.optimize(dsl.inverse_helmholtz_program(5))
    inputs = [
        n for n in prog.toposort() if isinstance(n, ir.Input)
    ]
    names = [n.name for n in inputs]
    assert sorted(names) == ["D", "S", "u"]  # S appears once after CSE


def test_optimize_idempotent():
    prog = rewrite.optimize(dsl.inverse_helmholtz_program(5))
    again = rewrite.optimize(prog)
    assert again.total_flops() == prog.total_flops()


# ---------------------------------------------------------------------------
# hypothesis: random contraction-of-products programs
# ---------------------------------------------------------------------------

@st.composite
def chain_program(draw):
    """Random (M1 # M2 # x) . pairs program over small dims."""
    p = draw(st.integers(2, 4))
    n_mats = draw(st.integers(1, 3))
    b = dsl.Builder()
    x = b.input("x", (p,) * n_mats)
    node = x
    mats = []
    for i in range(n_mats):
        m = b.input(f"M{i}", (p, p))
        mats.append(m)
        node = ir.prod(m, node)
    # contract each matrix's second axis with one x axis
    pairs = []
    for i in range(n_mats):
        mat_col = 2 * i + 1
        x_axis = 2 * n_mats + i
        pairs.append((mat_col, x_axis))
    out = ir.cont(node, pairs)
    b.output("y", out)
    return b.program(), p, n_mats


@given(chain_program(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_random_chain_factorization_semantics(prog_info, seed):
    prog, p, n_mats = prog_info
    opt = rewrite.optimize(prog)
    assert opt.total_flops() <= prog.total_flops()
    rng = np.random.default_rng(seed)
    env = {"x": rng.uniform(-1, 1, (p,) * n_mats).astype(np.float64)}
    for i in range(n_mats):
        env[f"M{i}"] = rng.uniform(-1, 1, (p, p)).astype(np.float64)
    a = emit.evaluate(prog, env, F32)["y"]
    bb = emit.evaluate(opt, env, F32)["y"]
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                               rtol=1e-3, atol=1e-4)
