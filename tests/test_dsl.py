"""CFDlang front-end: parsing, verification, error paths."""
import pytest

from repro.core import dsl, ir


def test_parse_inverse_helmholtz():
    prog = dsl.inverse_helmholtz_program(7)
    assert set(prog.inputs) == {"S", "D", "u"}
    assert set(prog.outputs) == {"v"}
    assert prog.outputs["v"].shape == (7, 7, 7)
    assert prog.element_vars == ("u", "D", "v")


def test_parse_preserves_literal_structure():
    """The front-end must not canonicalize (paper section 3.3.1): the
    contraction of the rank-9 outer product appears literally."""
    prog = dsl.inverse_helmholtz_program(5)
    # literal cost is O(p^9)-dominated, far above the factorized count
    assert prog.total_flops() > 5 ** 9


def test_interpolation_and_gradient_parse():
    p1 = dsl.interpolation_program(7, 9)
    assert p1.outputs["v"].shape == (9, 9, 9)
    p2 = dsl.gradient_program(8, 7, 6)
    assert p2.outputs["gx"].shape == (8, 7, 6)
    assert p2.outputs["gy"].shape == (7, 8, 6)
    assert p2.outputs["gz"].shape == (6, 8, 7)


def test_parse_errors():
    with pytest.raises(dsl.ParseError):
        dsl.parse("var input A : [3 3]\nB = A")        # undeclared B
    with pytest.raises(dsl.ParseError):
        dsl.parse("var input A : [3 3]\nvar output B : [3]\nB = A")
    with pytest.raises(dsl.ParseError):
        dsl.parse("var input A : [3 3]\nvar input A : [3 3]")  # dup
    with pytest.raises(dsl.ParseError):
        # contraction of mismatched dims
        dsl.parse(
            "var input A : [3 4]\nvar output b : [1]\nb = A . [[0 1]]"
        )


def test_stray_leading_sign_rejected_clearly():
    """'-' (and '+') are binary-only: a stray leading sign must raise a
    clear ParseError instead of the old 'unknown identifier' cascade."""
    src = "var input A : [3 3]\nvar output b : [3 3]\nb = {sign} A"
    for sign in ("-", "+"):
        with pytest.raises(dsl.ParseError, match="binary operator"):
            dsl.parse(src.format(sign=sign))
    with pytest.raises(dsl.ParseError, match="binary operator"):
        dsl.parse("var input A : [3 3]\nvar output b : [3 3]\nb = A * - A")
    # negative integers inside shapes/pairs fail with the same clarity
    with pytest.raises(dsl.ParseError, match="unsigned"):
        dsl.parse("var input A : [-3]")
    with pytest.raises(dsl.ParseError, match="unsigned"):
        dsl.parse(
            "var input A : [3 3]\nvar output b : []\nb = A . [[0 -1]]"
        )


def test_blank_and_comment_only_programs_rejected():
    for src in ("", "   \n\t", "// just a comment\n// another\n"):
        with pytest.raises(dsl.ParseError, match="empty program"):
            dsl.parse(src)


def test_elem_qualifier_marks_element_vars():
    src = """
    var input S : [3 3]
    var input elem u : [3 3 3]
    var output elem v : [3 3 3]
    v = S # S # S # u . [[1 6][3 7][5 8]]
    """
    prog = dsl.parse(src)
    assert prog.element_vars == ("u", "v")
    # markers merge with (and precede) the element_vars argument
    prog = dsl.parse(src, element_vars=("v", "u"))
    assert prog.element_vars == ("u", "v")
    # a variable literally named 'elem' still declares fine
    ok = dsl.parse(
        "var input elem : [2 2]\nvar output o : [2 2]\no = elem * elem"
    )
    assert "elem" in ok.inputs
    with pytest.raises(dsl.ParseError, match="inputs/outputs only"):
        dsl.parse("var elem t : [2 2]")


def test_use_before_assignment_rejected():
    src = """
    var input A : [3 3]
    var output v : [3 3]
    var t : [3 3]
    v = t * A
    """
    with pytest.raises(dsl.ParseError):
        dsl.parse(src)


def test_builder_matmul_matches_paper_encoding():
    b = dsl.Builder()
    A = b.input("A", (4, 5))
    B = b.input("B", (5, 6))
    b.output("C", b.matmul(A, B))
    prog = b.program()
    assert prog.outputs["C"].shape == (4, 6)


def test_hadamard_and_add():
    src = """
    var input A : [3 3]
    var input B : [3 3]
    var output C : [3 3]
    C = A * B + A
    """
    prog = dsl.parse(src)
    assert isinstance(prog.outputs["C"], ir.Ewise)
