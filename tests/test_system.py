"""End-to-end behaviour tests for the paper's system: DSL source in,
batched sharded execution out; plus the LM vertical slice."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.cfd import reference
from repro.core import api
from repro.core.precision import FIXED32, enable_x64
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime.train import init_train_state, make_train_step


def test_dsl_to_executable_end_to_end(rng):
    """The paper's headline flow: CFDlang text -> optimized batched
    executable (Fig. 5), validated against Eq. (1a)-(1c)."""
    p = 7
    src = f"""
    var input S : [{p} {p}]
    var input D : [{p} {p} {p}]
    var input u : [{p} {p} {p}]
    var output v : [{p} {p} {p}]
    var t : [{p} {p} {p}]
    var r : [{p} {p} {p}]
    t = S # S # S # u . [[1 6][3 7][5 8]]
    r = D * t
    v = S # S # S # r . [[0 6][2 7][4 8]]
    """
    compiled = api.compile_cfdlang(src, element_vars=("u", "D", "v"))
    E = 16
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    got = np.asarray(compiled(S=S, D=D, u=u)["v"])
    want = reference.inverse_helmholtz_batch(
        S.astype(np.float64), D.astype(np.float64), u.astype(np.float64)
    )
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)
    # paper op-count contract
    assert compiled.program.total_flops() == (12 * p + 1) * p ** 3


def test_fixed_point_flow_end_to_end(rng):
    """DSL -> fixed-point executable (the paper's precision knob)."""
    p = 5
    with enable_x64(True):
        compiled = api.compile_cfdlang(
            api.dsl.INVERSE_HELMHOLTZ_SRC.format(p=p),
            element_vars=("u", "D", "v"), policy=FIXED32, jit=False,
        )
        S = rng.uniform(-1, 1, (p, p))
        D = rng.uniform(-1, 1, (p, p, p))
        u = rng.uniform(-1, 1, (p, p, p))
        env = {k: FIXED32.encode(v) for k, v in
               {"S": S, "D": D, "u": u}.items()}
        got = np.asarray(FIXED32.decode(compiled.element_fn(env)["v"]))
    want = reference.inverse_helmholtz(S, D, u)
    assert np.mean((got - want) ** 2) < 1e-9


def test_lm_vertical_slice_loss_decreases(rng):
    cfg = configs.get_smoke("qwen3-14b")
    model = build_model(cfg, attn_impl="xla")
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(
        model, AdamWConfig(lr=3e-3, warmup_steps=1, total_steps=30)
    ))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)), jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    losses = []
    for _ in range(12):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.8
