"""Property-based invariants for memory.layout (hypothesis; skipped
cleanly where hypothesis is not installed):

  * padded record sizes are burst-multiples (and minimal),
  * auto_batch_elements never overflows a pseudo-channel,
  * channel assignment never double-books a channel within one replica
    set, for single programs and for chains sharing one allocator.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import ir  # noqa: E402
from repro.memory import channels, layout  # noqa: E402
from repro.memory.chain import ProgramChain, plan_chain  # noqa: E402
from repro.memory.dse import make_plan  # noqa: E402


# -- strategies --------------------------------------------------------------

bursts = st.sampled_from([1, 2, 16, 64, 128, 512])
targets = st.builds(
    lambda burst, n_ch, cap_mib: channels.ALVEO_U280.with_(
        burst_bytes=burst, n_channels=n_ch,
        hbm_bytes=n_ch * cap_mib * 2 ** 20,
    ),
    burst=bursts,
    n_ch=st.integers(1, 64),
    cap_mib=st.integers(1, 512),
)


@st.composite
def small_programs(draw):
    """Random multi-stream programs: k element inputs of assorted shapes,
    each transposed into an element output, plus optional shared
    operands -- enough structure to exercise every layout path."""
    n_elem = draw(st.integers(1, 4))
    n_shared = draw(st.integers(0, 2))
    inputs = {}
    outputs = {}
    elem_vars = []
    for i in range(n_elem):
        shape = tuple(
            draw(st.integers(1, 12))
            for _ in range(draw(st.integers(1, 3)))
        )
        x = ir.Input(shape=shape, name=f"x{i}")
        inputs[f"x{i}"] = x
        perm = list(range(len(shape)))[::-1]
        outputs[f"y{i}"] = ir.transpose(x, perm)
        elem_vars += [f"x{i}", f"y{i}"]
    for i in range(n_shared):
        shape = (draw(st.integers(1, 8)), draw(st.integers(1, 8)))
        inputs[f"s{i}"] = ir.Input(shape=shape, name=f"s{i}")
    return ir.Program(
        inputs=inputs, outputs=outputs, element_vars=tuple(elem_vars)
    )


# -- padding -----------------------------------------------------------------


@given(nbytes=st.integers(0, 1 << 20), burst=bursts)
def test_pad_to_burst_is_minimal_burst_multiple(nbytes, burst):
    t = channels.ALVEO_U280.with_(burst_bytes=burst)
    padded = channels.pad_to_burst(nbytes, t)
    assert padded % burst == 0
    assert padded >= nbytes
    assert padded - nbytes < burst  # minimal: one burst of slack at most


@given(prog=small_programs(), target=targets,
       bps=st.sampled_from([2, 4, 8]))
@settings(max_examples=50, deadline=None)
def test_padded_records_are_burst_multiples(prog, target, bps):
    bufs = layout.build_buffers(
        prog, target, bytes_per_scalar=bps, batch_elements=7,
        prefetch_depth=1,
    )
    for b in bufs:
        assert b.padded_bytes % target.burst_bytes == 0
        assert b.padded_bytes >= b.element_bytes
        if b.role != "shared":
            assert b.batch_bytes == b.padded_bytes * 7


# -- batch sizing ------------------------------------------------------------


@given(prog=small_programs(), target=targets,
       bps=st.sampled_from([2, 4, 8]),
       n_eq=st.one_of(st.none(), st.integers(1, 1 << 22)))
@settings(max_examples=50, deadline=None)
def test_auto_batch_never_overflows_channel(prog, target, bps, n_eq):
    e = layout.auto_batch_elements(
        prog, target, bytes_per_scalar=bps, n_eq=n_eq
    )
    per = layout.stream_bytes_per_element(prog, bps)
    assert e >= 1
    if n_eq is not None:
        assert e <= max(1, n_eq)
    # E fills at most one pseudo-channel; E=1 is the floor when even a
    # single element's streams exceed the channel (capacity feasibility
    # is the DSE's global check, not the sizing rule's)
    if e > 1:
        assert e * per <= target.channel_bytes
    if n_eq is None and (e + 1) * per <= target.channel_bytes:
        pytest.fail("E not maximal for the channel")


# -- channel assignment ------------------------------------------------------


@given(prog=small_programs(), target=targets,
       depth=st.integers(0, 4))
@settings(max_examples=50, deadline=None)
def test_channels_never_double_booked(prog, target, depth):
    bufs = layout.build_buffers(
        prog, target, bytes_per_scalar=4, batch_elements=3,
        prefetch_depth=depth,
    )
    for b in bufs:
        assert len(b.channels) == len(set(b.channels)), b.name
        assert all(0 <= c < target.n_channels for c in b.channels)


@given(n_channels=st.integers(1, 64),
       takes=st.lists(st.integers(1, 100), min_size=1, max_size=20))
def test_allocator_takes_are_duplicate_free(n_channels, takes):
    alloc = layout.ChannelAllocator(n_channels)
    for count in takes:
        ids = alloc.take(count)
        assert len(ids) == len(set(ids))
        assert len(ids) == min(max(1, count), n_channels)


@given(p=st.sampled_from([3, 5, 7]), e=st.integers(1, 4096),
       target=targets)
@settings(max_examples=25, deadline=None)
def test_plan_blocks_divide_e_and_fit_vmem(p, e, target):
    plan = make_plan(p, target=target, batch_elements=e)
    assert plan.block_elements >= 1
    assert plan.batch_elements % plan.block_elements == 0
    # the ALVEO-derived targets keep 43 MiB of PLM, so even the BE=1
    # floor fits; the chosen block must always respect the capacity
    assert plan.block_working_set_bytes <= target.vmem_bytes


@given(depth=st.integers(0, 3), e=st.integers(1, 512))
@settings(max_examples=20, deadline=None)
def test_chain_buffers_unique_names_and_channels(depth, e):
    from repro.cfd import operators

    chain = operators.build_cfd_chain(5)
    plan = plan_chain(
        chain, target=channels.ALVEO_U280, batch_elements=e,
        prefetch_depth=depth,
    )
    names = [b.name for b in plan.buffers]
    assert len(names) == len(set(names))
    for b in plan.buffers:
        assert len(b.channels) == len(set(b.channels))
