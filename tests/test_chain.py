"""Multi-operator chain planning + the run_chain driver: residency,
co-sized E, conflict-free placement, bitwise equivalence with the
unchained reference, and the plan-driven Pallas block size."""
import numpy as np
import pytest

from repro.cfd import operators, simulation
from repro.memory import chain as mchain
from repro.memory import channels, dse, layout


@pytest.fixture(scope="module")
def cfd_chain():
    return operators.build_cfd_chain(5)


def _chain_inputs(chain, n, p, rng):
    inputs = {
        "interp.u": rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32),
        "helmholtz.D": rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32),
    }
    shared = {
        name: rng.uniform(-1, 1, node.shape).astype(np.float32)
        for name, node in sorted(chain.shared_operands().items())
    }
    return inputs, shared


# ---------------------------------------------------------------------------
# chain structure
# ---------------------------------------------------------------------------


def test_chain_structure(cfd_chain):
    ch = cfd_chain
    assert ch.name == "interp->grad->helmholtz"
    # bound streams (flow-derived from the pipeline source): interp's w
    # feeds the gradient, the gradient's gx feeds the Helmholtz solve
    assert ch.resolved[1] == {"w": (0, "w")}
    assert ch.resolved[2] == {"gx": (1, "gx")}
    assert [n for n, _ in ch.resident_outputs(0)] == ["w"]
    assert [n for n, _ in ch.resident_outputs(1)] == ["gx"]
    # fringe: only unbound element vars touch the host
    assert [n for n, _ in ch.host_element_inputs(0)] == ["u"]
    assert [n for n, _ in ch.host_element_inputs(1)] == []
    assert [n for n, _ in ch.host_element_inputs(2)] == ["D"]
    assert [n for n, _ in ch.chain_outputs(1)] == ["gy", "gz"]
    assert [n for n, _ in ch.chain_outputs(2)] == ["v"]
    assert sorted(ch.shared_operands()) == ["A", "Dx", "Dy", "Dz", "S"]


def test_chain_rejects_bad_bindings():
    interp = operators.build_interpolation(5, 5)
    helm = operators.build_inverse_helmholtz(7)  # shape mismatch vs p=5
    with pytest.raises(mchain.ChainError):
        mchain.ProgramChain([
            ("a", interp), ("b", helm, {"u": "a.v"}),
        ])
    with pytest.raises(mchain.ChainError):
        mchain.ProgramChain([
            ("a", interp), ("b", interp, {"u": "nosuch.v"}),
        ])
    with pytest.raises(mchain.ChainError):  # unqualified binding
        mchain.ProgramChain([
            ("a", interp), ("b", interp, {"u": "v"}),
        ])
    with pytest.raises(mchain.ChainError):  # duplicate stage names
        mchain.ProgramChain([("a", interp), ("a", interp)])


def test_chain_auto_binding_by_name():
    """An input named like an earlier output binds without an explicit
    bindings entry (most recent producer wins)."""
    a = operators.build_interpolation(5, 5)  # u -> v
    b = operators.build_inverse_helmholtz(5)  # u, D -> v ... no 'v' input
    # gradient consumes 'u'; interpolation produces 'v' -- no auto-bind
    chain = mchain.ProgramChain([("s0", a), ("s1", b)])
    assert chain.resolved[1] == {}  # nothing matched by name
    # a second interpolation re-consuming 'u' does NOT bind to s0's 'v'
    chain2 = mchain.ProgramChain([("s0", a), ("s1", a)])
    assert chain2.resolved[1] == {}


# ---------------------------------------------------------------------------
# chain plan: residency, E co-sizing, placement
# ---------------------------------------------------------------------------


def test_chain_plan_fewer_host_bytes_than_standalone(cfd_chain):
    """Acceptance: the chain plan's host-stream bytes are strictly fewer
    than the sum of the three standalone plans at the same E."""
    E = 128
    t = channels.ALVEO_U280
    plan = mchain.plan_chain(cfd_chain, target=t, batch_elements=E)
    standalone = sum(
        dse.make_plan(
            s.program, target=t, batch_elements=E, operator_name=s.name
        ).host_stream_bytes
        for s in cfd_chain.stages
    )
    assert plan.host_stream_bytes < standalone
    # exactly the bound streams stay resident: interp.w and grad.gx,
    # each saving one host write + one host read
    resident = [b for b in plan.buffers if b.role == "resident"]
    assert sorted(b.name for b in resident) == ["grad.gx", "interp.w"]
    assert standalone - plan.host_stream_bytes == 2 * sum(
        b.batch_bytes for b in resident
    )
    assert plan.resident_stream_bytes == sum(b.batch_bytes for b in resident)


def test_chain_cosized_e_fits_every_stage(cfd_chain):
    """The shared E (before block padding) satisfies the channel rule
    for each stage, at least one stage is tight (E is maximal), and the
    padded E is a multiple of every stage's VMEM block."""
    t = channels.ALVEO_U280
    plan = mchain.plan_chain(cfd_chain, target=t)
    base = plan.batch_elements - plan.batch_pad_elements
    tight = False
    for i in range(len(cfd_chain.stages)):
        per = cfd_chain.stage_stream_bytes_per_element(i, 4)
        assert base * per <= t.channel_bytes
        if (base + 1) * per > t.channel_bytes:
            tight = True
    assert tight
    for sp in plan.stages:
        assert plan.batch_elements % sp.block_elements == 0
    # the padder's contract: for the largest stage cap, the chosen E's
    # block divisor is never below half the cap (prime-ish E padded away)
    max_cap = max(
        layout.vmem_block_elements(s.program, t, bytes_per_scalar=4)
        for s in cfd_chain.stages
    )
    blk = layout.largest_divisor_leq(plan.batch_elements, max_cap)
    assert 2 * blk >= min(max_cap, plan.batch_elements)


def test_chain_placement_no_conflicts(cfd_chain):
    """No channel double-booked within a replica set; shared operands
    placed exactly once chain-wide."""
    plan = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, batch_elements=256
    )
    names = [b.name for b in plan.buffers]
    assert len(names) == len(set(names))
    for b in plan.buffers:
        assert len(b.channels) == len(set(b.channels))
    shared = [b for b in plan.buffers if b.role == "shared"]
    assert sorted(b.name for b in shared) == ["A", "Dx", "Dy", "Dz", "S"]
    # consecutive buffers round-robin instead of piling on channel 0
    first_channels = [b.channels[0] for b in plan.buffers]
    assert len(set(first_channels)) > 1


def test_chain_plan_determinism_and_report(cfd_chain):
    kw = dict(target=channels.ALVEO_U280, batch_elements=128, n_eq=1024)
    a = mchain.plan_chain(cfd_chain, **kw)
    b = mchain.plan_chain(cfd_chain, **kw)
    assert a == b
    assert a.report() == b.report()
    rep = a.report()
    assert "ChainPlan interp->grad->helmholtz" in rep
    assert "resident" in rep and "stage helmholtz" in rep


def test_chain_infeasible_reported(cfd_chain):
    tiny = channels.ALVEO_U280.with_(hbm_bytes=2 ** 20, n_channels=4)
    plan = mchain.plan_chain(cfd_chain, target=tiny, batch_elements=4096)
    assert not plan.feasible
    assert "exceeds" in plan.infeasible_reason
    assert "NO" in plan.report()


def test_chain_per_stage_depths_and_backends(cfd_chain):
    plan = mchain.plan_chain(
        cfd_chain, target=channels.ALVEO_U280, batch_elements=64,
        backends=("xla", "staged", "staged"), prefetch_depth=(0, 1, 2),
    )
    assert [sp.backend for sp in plan.stages] == ["xla", "staged", "staged"]
    assert [sp.prefetch_depth for sp in plan.stages] == [0, 1, 2]
    # the staged Helmholtz exposes its group-boundary intermediates (the
    # gradient's groups all end at program outputs, so it has none)
    assert any(
        b.role == "inter" for b in plan.stages[2].buffers
    )


# ---------------------------------------------------------------------------
# run_chain: the whole pipeline off one plan
# ---------------------------------------------------------------------------


def test_run_chain_bitwise_matches_unchained(cfd_chain, rng):
    """Acceptance: chained execution (intermediates resident on device)
    is bitwise-identical at float32 to running the three compiled
    operators separately with host round-trips between them."""
    p, E, n_b = 5, 16, 3
    n = E * n_b
    chain = cfd_chain
    inputs, shared = _chain_inputs(chain, n, p, rng)
    plan = mchain.plan_chain(
        chain, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
        prefetch_depth=2,
    )
    res = simulation.run_chain(
        chain, plan, inputs=inputs, shared=shared, collect_outputs=True
    )
    assert res.batches == n_b and res.elements == n

    interp, grad, helm = (s.compiled for s in chain.stages)
    ref = {"grad.gy": [], "grad.gz": [], "helmholtz.v": []}
    for b in range(n_b):
        sl = slice(b * E, (b + 1) * E)
        w = np.asarray(interp.batched_fn(
            {"A": shared["A"], "u": inputs["interp.u"][sl]})["w"])
        g = grad.batched_fn({
            "Dx": shared["Dx"], "Dy": shared["Dy"], "Dz": shared["Dz"],
            "w": w,
        })
        ref["grad.gy"].append(np.asarray(g["gy"]))
        ref["grad.gz"].append(np.asarray(g["gz"]))
        hv = helm.batched_fn({
            "S": shared["S"], "D": inputs["helmholtz.D"][sl],
            "gx": np.asarray(g["gx"]),
        })["v"]
        ref["helmholtz.v"].append(np.asarray(hv))
    for q in ref:
        want = np.concatenate(ref[q])
        assert want.dtype == res.outputs[q].dtype == np.float32
        assert np.array_equal(want, res.outputs[q]), q


def test_run_chain_stage_pipelined_bitwise_matches_serial(cfd_chain, rng):
    """Acceptance: cross-batch stage pipelining (stage i of batch k
    dispatched with stage i+1 of batch k-1) is bitwise-equal at float32
    to the serial back-to-back schedule on the CFD chain."""
    p, E, n_b = 5, 16, 4
    n = E * n_b
    inputs, shared = _chain_inputs(cfd_chain, n, p, rng)
    plan = mchain.plan_chain(
        cfd_chain, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
        prefetch_depth=(2, 1, 1),
    )
    assert plan.pipeline.pipelined
    assert plan.pipeline.stage_skews == (0, 1, 2)
    assert plan.cost.t_overlapped <= plan.cost.t_back_to_back
    piped = simulation.run_chain(
        cfd_chain, plan, inputs=inputs, shared=shared, collect_outputs=True
    )
    assert piped.pipelined_stages
    serial = simulation.run_chain(
        cfd_chain, plan, inputs=inputs, shared=shared,
        collect_outputs=True, pipeline_stages=False,
    )
    assert not serial.pipelined_stages
    assert piped.outputs.keys() == serial.outputs.keys()
    for q in serial.outputs:
        assert piped.outputs[q].dtype == serial.outputs[q].dtype
        assert np.array_equal(piped.outputs[q], serial.outputs[q]), q
    # the fully serial plan (all K=0) runs the serial schedule by default
    flat = mchain.plan_chain(
        cfd_chain, target=channels.CPU_HOST, batch_elements=E, n_eq=n,
        prefetch_depth=0,
    )
    assert not flat.pipeline.pipelined
    base = simulation.run_chain(
        cfd_chain, flat, inputs=inputs, shared=shared, collect_outputs=True
    )
    assert not base.pipelined_stages
    # forcing the mode on cannot pipeline a plan with no inter-stage
    # rings: execution and the reported flag stay serial
    forced = simulation.run_chain(
        cfd_chain, flat, inputs=inputs, shared=shared,
        max_batches=1, pipeline_stages=True,
    )
    assert not forced.pipelined_stages
    for q in serial.outputs:
        assert np.array_equal(base.outputs[q], serial.outputs[q]), q


def test_run_chain_checksums_invariant_to_prefetch(cfd_chain, rng):
    p, E, n_b = 5, 8, 3
    inputs, shared = _chain_inputs(cfd_chain, E * n_b, p, rng)
    sums = {}
    for depth in (0, 2):
        plan = mchain.plan_chain(
            cfd_chain, target=channels.CPU_HOST, batch_elements=E,
            prefetch_depth=depth, n_eq=E * n_b,
        )
        res = simulation.run_chain(
            cfd_chain, plan, inputs=inputs, shared=shared
        )
        sums[depth] = res.checksums
    assert sums[0].keys() == sums[2].keys()
    for q in sums[0]:
        assert sums[0][q] == pytest.approx(sums[2][q], abs=1e-5)


def test_run_chain_warns_on_backend_mismatch(cfd_chain, rng):
    """A plan for backends the chain was not compiled with still runs
    (numerically identical programs) but flags the misattribution."""
    p, E = 5, 8
    inputs, shared = _chain_inputs(cfd_chain, E, p, rng)
    plan = mchain.plan_chain(
        cfd_chain, target=channels.CPU_HOST, batch_elements=E,
        backends=("xla", "staged", "xla"), n_eq=E,
    )
    with pytest.warns(RuntimeWarning, match="differ from the compiled"):
        simulation.run_chain(cfd_chain, plan, inputs=inputs, shared=shared)


def test_run_chain_tolerates_plan_with_different_stage_count(cfd_chain, rng):
    """Regression: a pipelined plan from a differently-staged compile
    (stage count != the chain's) still executes the compiled chain as
    the mismatch warning promises, spreading the plan's deepest K."""
    p, E = 5, 8
    inputs, shared = _chain_inputs(cfd_chain, E * 2, p, rng)
    two = mchain.ProgramChain(cfd_chain.stages[:2])  # interp -> grad
    plan = mchain.plan_chain(
        two, target=channels.CPU_HOST, batch_elements=E,
        prefetch_depth=1, n_eq=E * 2,
    )
    assert plan.pipeline.pipelined and len(plan.stages) == 2
    with pytest.warns(RuntimeWarning, match="differ from the compiled"):
        res = simulation.run_chain(
            cfd_chain, plan, inputs=inputs, shared=shared, n_eq=E * 2
        )
    assert res.batches == 2 and res.pipelined_stages
    assert all(np.isfinite(v) for v in res.checksums.values())


def test_run_chain_auto_plans_when_missing(cfd_chain):
    res = simulation.run_chain(cfd_chain, n_eq=64, max_batches=2)
    assert res.plan is not None
    assert res.plan.batch_elements >= 1
    assert set(res.checksums) == {"grad.gy", "grad.gz", "helmholtz.v"}
    assert all(np.isfinite(v) for v in res.checksums.values())


def test_run_chain_auto_e_bounded_by_inputs(cfd_chain, rng):
    """Regression: with inputs but no n_eq, the auto-sized E is capped
    by the data so the element accounting is honest."""
    p, n = 5, 48
    inputs, shared = _chain_inputs(cfd_chain, n, p, rng)
    res = simulation.run_chain(cfd_chain, inputs=inputs, shared=shared)
    assert res.plan.batch_elements <= n
    assert res.elements == res.batches * res.plan.batch_elements <= n
    # an explicitly oversized plan is rejected rather than silently
    # computing on fewer elements than it reports
    big = mchain.plan_chain(
        cfd_chain, target=channels.CPU_HOST, batch_elements=4 * n
    )
    with pytest.raises(ValueError, match="exceeds the provided input"):
        simulation.run_chain(cfd_chain, big, inputs=inputs, shared=shared)
    # an oversized n_eq is clamped to the data instead of running empty
    # batches past the arrays' end
    small = mchain.plan_chain(
        cfd_chain, target=channels.CPU_HOST, batch_elements=16, n_eq=n
    )
    res = simulation.run_chain(
        cfd_chain, small, inputs=inputs, shared=shared, n_eq=16 * n
    )
    assert res.elements <= n


def test_plan_infeasible_when_block_floor_exceeds_vmem():
    """Even the BE=1 block must fit on-chip, or the plan says so."""
    tiny = channels.ALVEO_U280.with_(vmem_bytes=8192)
    plan = dse.make_plan(11, target=tiny, batch_elements=64)
    assert not plan.feasible
    assert "block working set" in plan.infeasible_reason
    chain_plan = mchain.plan_chain(
        operators.build_cfd_chain(11), target=tiny, batch_elements=64
    )
    assert not chain_plan.feasible
    assert "block working set" in chain_plan.infeasible_reason


# ---------------------------------------------------------------------------
# VMEM-budgeted Pallas block
# ---------------------------------------------------------------------------


def test_plan_block_elements_fits_vmem():
    """Acceptance: the plan-chosen Pallas block's working set fits the
    target's VMEM, divides E, and shows up in the report."""
    t = channels.TPU_V5E
    plan = dse.make_plan(
        11, target=t, backend="pallas", batch_elements=4096
    )
    assert plan.block_elements > 1
    assert plan.batch_elements % plan.block_elements == 0
    assert plan.block_working_set_bytes <= t.vmem_bytes
    assert f"vmem block BE={plan.block_elements}" in plan.report()
    # maximal: the next power of two would blow the reserve budget
    from repro.kernels.helmholtz import ops as hops
    assert hops.block_working_set_bytes(
        11, plan.block_elements
    ) == plan.block_working_set_bytes
    bigger = min(plan.block_elements * 2, plan.batch_elements)
    if bigger > plan.block_elements:
        assert hops.block_working_set_bytes(11, bigger) > t.vmem_bytes // 2


def test_pallas_block_resolution_prefers_plan():
    plan = dse.make_plan(
        5, target=channels.TPU_V5E, backend="pallas", batch_elements=1024
    )
    assert operators.pallas_block_elements(5, plan) == plan.block_elements
    assert operators.pallas_block_elements(
        5, None, vmem_bytes=channels.TPU_V5E.vmem_bytes
    ) >= plan.block_elements  # unconstrained by E divisibility
    from repro.kernels.helmholtz.ops import DEFAULT_BLOCK_ELEMENTS
    assert operators.pallas_block_elements(5) == DEFAULT_BLOCK_ELEMENTS


def test_pallas_backend_runs_with_plan_block(rng):
    """The plan-driven block produces correct results through the
    compiled pallas path (interpret mode on CPU)."""
    p, E = 5, 8
    plan = dse.make_plan(
        p, target=channels.CPU_HOST, backend="pallas", batch_elements=E
    )
    assert plan.block_elements >= 1
    assert E % plan.block_elements == 0
    from repro.kernels.helmholtz import ops as hops
    impl = hops.make_pallas_impl(
        impl="interpret", block_elements=plan.block_elements
    )
    S = rng.uniform(-1, 1, (p, p)).astype(np.float32)
    D = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    u = rng.uniform(-1, 1, (E, p, p, p)).astype(np.float32)
    got = np.asarray(impl({"S": S, "D": D, "u": u})["v"])
    ref = operators.build_inverse_helmholtz(p)
    want = np.asarray(ref.batched_fn({"S": S, "D": D, "u": u})["v"])
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_chain_plan_block_reaches_pallas_stage(monkeypatch):
    """Regression: rebuilding the chain with its ChainPlan threads the
    plan's per-stage VMEM block into the Pallas Helmholtz kernel (the
    compiled-before-planning chain cannot know it)."""
    p, E = 5, 192  # not divisible by the kernel default of 128
    plan_only = operators.build_cfd_chain(p)
    plan = mchain.plan_chain(
        plan_only, target=channels.TPU_V5E, batch_elements=E,
        backends=("xla", "xla", "pallas"),
    )
    blk = operators.chain_stage_block_elements(plan, "helmholtz")
    assert blk == plan.stages[2].block_elements
    assert blk is not None and E % blk == 0
    assert operators.chain_stage_block_elements(plan, "nosuch") is None
    assert operators.chain_stage_block_elements(None, "helmholtz") is None

    seen = {}
    from repro.kernels.helmholtz import ops as hops
    real = hops.make_pallas_impl

    def spy(impl="auto", block_elements=hops.DEFAULT_BLOCK_ELEMENTS):
        seen["block_elements"] = block_elements
        return real(impl=impl, block_elements=block_elements)

    monkeypatch.setattr(
        "repro.cfd.operators.helmholtz_ops.make_pallas_impl", spy
    )
    operators.build_cfd_chain(
        p, backends=("xla", "xla", "pallas"), chain_plan=plan
    )
    assert seen["block_elements"] == blk


def test_layout_vmem_block_matches_kernel_formula():
    """memory.layout's generic block working set agrees with the
    Helmholtz kernel's closed form, so the plan and the kernel can
    never disagree about what fits."""
    from repro.core import dsl, rewrite
    from repro.kernels.helmholtz import ops as hops

    for p in (5, 7, 11):
        prog = rewrite.optimize(dsl.inverse_helmholtz_program(p))
        for be in (1, 8, 64):
            assert layout.block_working_set_bytes(
                prog, be, bytes_per_scalar=4
            ) == hops.block_working_set_bytes(p, be)


# ---------------------------------------------------------------------------
# measured contention: profile-store stage samples re-price steady state
# ---------------------------------------------------------------------------

def test_contention_fit_round_trip(cfd_chain, tmp_path):
    """Record synthetic per-stage measurements into a ProfileStore, plan
    with profile=, and the fitted multipliers invert the steady-state
    model exactly: max(t_host, k*dev) + t_overhead == measured."""
    from repro.trace.profile import ProfileStore

    kw = dict(target=channels.ALVEO_U280, batch_elements=128, n_eq=1024,
              prefetch_depth=1)
    plan = mchain.plan_chain(cfd_chain, **kw)
    assert plan.cost.pipelined_stages and plan.cost.contention

    store = ProfileStore(path=str(tmp_path / "p.json"), fingerprint="fp")
    k_true = {}
    samples = []
    for i, sp in enumerate(plan.stages):
        c = plan.cost.stages[i]
        dev = max(c.t_compute, c.t_hbm)
        # device-bound evidence: the device part must clear the host link
        k = max(2.0, 1.5 * c.t_host / dev) + 0.5 * i
        k_true[sp.name] = k
        samples.append({
            "scope": f"stage:{sp.name}",
            "predicted_s": c.t_pipelined,
            "measured_s": c.t_overhead + k * dev,
            "bottleneck": c.bottleneck,
        })
    # chain-level sample: the fit must ignore non-stage scopes
    samples.append({"scope": "chain", "predicted_s": 1.0,
                    "measured_s": 2.0, "bottleneck": "compute"})
    assert store.record(plan.target.name, plan.signature,
                        samples) == len(samples)

    fitted = mchain.plan_chain(cfd_chain, profile=store, **kw)
    assert len(fitted.cost.contention_fit) == len(fitted.stages)
    for i, sp in enumerate(fitted.stages):
        assert fitted.cost.contention_fit[i] == pytest.approx(
            k_true[sp.name])
    expect = tuple(
        max(c.t_host, k_true[sp.name] * max(c.t_compute, c.t_hbm))
        + c.t_overhead
        for sp, c in zip(fitted.stages, fitted.cost.stages)
    )
    assert fitted.cost.stage_steady_times == pytest.approx(expect)
    assert "contention fitted from profile" in fitted.report()
    # everything but the cost fit is the structural plan
    assert fitted.stages == plan.stages
    assert fitted.placement == plan.placement


def test_contention_fit_keeps_structural_without_evidence(cfd_chain,
                                                          tmp_path):
    """Host-bound samples say nothing about device sharing: the fit
    falls back to the placement's structural count per stage, and a
    store with no usable samples leaves the plan untouched."""
    from repro.trace.profile import ProfileStore

    kw = dict(target=channels.ALVEO_U280, batch_elements=128, n_eq=1024,
              prefetch_depth=1)
    plan = mchain.plan_chain(cfd_chain, **kw)
    store = ProfileStore(path=str(tmp_path / "p.json"), fingerprint="fp")
    host_bound = [{
        # measured below t_host: the link hides the device terms
        "scope": f"stage:{sp.name}",
        "predicted_s": 1.0,
        "measured_s": c.t_overhead + 0.5 * c.t_host if c.t_host else 1e-12,
        "bottleneck": "host-link",
    } for sp, c in zip(plan.stages, plan.cost.stages)]
    fit = mchain.fit_contention(
        plan.cost, [sp.name for sp in plan.stages], host_bound)
    assert fit == ()
    same = mchain.apply_profile_contention(plan, store)
    assert same == plan  # cold store: unchanged
    # one device-bound sample for one stage: the others keep structural
    c0 = plan.cost.stages[0]
    dev0 = max(c0.t_compute, c0.t_hbm)
    k0 = max(2.0, 2.0 * c0.t_host / dev0)
    partial = mchain.fit_contention(
        plan.cost, [sp.name for sp in plan.stages],
        [{"scope": f"stage:{plan.stages[0].name}",
          "predicted_s": 1.0, "measured_s": c0.t_overhead + k0 * dev0,
          "bottleneck": "compute"}])
    assert partial[0] == pytest.approx(k0)
    assert all(k == 0.0 for k in partial[1:])
    import dataclasses as _dc
    cost = _dc.replace(plan.cost, contention_fit=partial)
    # unfitted stages price with the structural count, fitted with k0
    assert cost.stage_steady_times[1:] == plan.cost.stage_steady_times[1:]
    assert cost.stage_steady_times[0] == pytest.approx(
        max(c0.t_host, k0 * dev0) + c0.t_overhead)
