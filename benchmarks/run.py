"""Benchmark harness -- one entry per paper table/figure.

  tab2_ladder      Fig. 15 / Table 2: the optimization ladder
                   (baseline -> double-buffer -> dataflow 1/2/3/7)
  fig16_precision  Fig. 16 / Table 4: precision x polynomial degree
  fig17_multicu    Fig. 17 / Table 5: CU replication (element-sharding)
  fig19_kernels    Fig. 19: Inverse Helmholtz / Interpolation / Gradient
  memplan_ladder   Figs. 14-15: the same ladder driven by MemoryPlans
                   (repro.memory), plus the machine's DSE winner
  chain_ladder     Sec. 5: the composed interpolation -> gradient ->
                   inverse-Helmholtz application planned as one
                   ProgramChain (inter-stage streams HBM-resident) vs
                   the unchained host-round-trip baseline; also writes
                   chain_ladder.json (CI uploads it as an artifact)
  flow_ladder      the repro.flow acceptance ladder: hand stage cuts vs
                   fully automatic source-to-system compilation; writes
                   flow_ladder.json
  lm_throughput    framework health: LM train/decode throughput (smoke)

Prints ``name,us_per_call,derived`` CSV rows (derived = GFLOPS under the
paper's Eq. (2) op-count model where applicable).  Wall times are CPU
(this container); the TPU-target numbers live in EXPERIMENTS.md
section Roofline, derived from the compiled dry-run.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.cfd import operators, reference  # noqa: E402
from repro.cfd.simulation import SimConfig, run_simulation  # noqa: E402
from repro.core.precision import POLICIES, enable_x64  # noqa: E402


def _time(fn, *args, warmup=2, iters=5, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _row(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _pred_fields(pred_s_per_batch, us_per_batch, E):
    """Prediction-accuracy fields for a timed ladder row: per-element
    predicted and measured seconds plus their symmetric ratio
    (``max(pred/meas, meas/pred)``, so over- and under-prediction are
    penalized alike).  CI bounds the ratio via $BENCH_PRED_ERROR_MAX."""
    meas_s = us_per_batch * 1e-6
    fields = {
        "predicted_s_per_element": pred_s_per_batch / E,
        "measured_s_per_element": meas_s / E,
    }
    if pred_s_per_batch > 0 and meas_s > 0:
        fields["prediction_error"] = max(
            pred_s_per_batch / meas_s, meas_s / pred_s_per_batch
        )
    return fields


_PROFILE_STORE = None


def _profile_record(plan, pred_s_per_batch, us_per_batch, scope):
    """Deposit a timed rung into the persistent profile store so later
    ``explore_chain(profile=...)`` runs rank with per-term corrections
    refit from this machine's history.  $BENCH_NO_PROFILE=1 disables;
    $REPRO_PROFILE redirects the store file.  Never fails the bench."""
    global _PROFILE_STORE
    import os

    if os.environ.get("BENCH_NO_PROFILE"):
        return
    try:
        if _PROFILE_STORE is None:
            from repro.trace import ProfileStore

            _PROFILE_STORE = ProfileStore()
        E = plan.batch_elements
        _PROFILE_STORE.record_measurement(
            plan, pred_s_per_batch / E, us_per_batch * 1e-6 / E,
            scope=f"bench:{scope}",
        )
    except Exception as e:
        print(f"# profile store skipped: {e}", file=sys.stderr)


def _helmholtz_data(p, E, rng, dtype=np.float32):
    return (
        rng.uniform(-1, 1, (p, p)).astype(dtype),
        rng.uniform(-1, 1, (E, p, p, p)).astype(dtype),
        rng.uniform(-1, 1, (E, p, p, p)).astype(dtype),
    )


def tab2_ladder() -> None:
    """The paper's cumulative-optimization ladder, CPU analogues:

    naive        literal O(p^6) contraction (pre-rewrite)
    serial_1elem factorized but one element per dispatch (serial CU)
    factorized   teil factorization -> GEMM chain, batched (paper baseline)
    dataflow_K   staged backend with K compute groups (1/2/3/7)
    """
    p, E = 11, 512
    rng = np.random.default_rng(0)
    S, D, u = _helmholtz_data(p, E, rng)
    flops = E * reference.paper_flops_per_element(p)

    # naive: literal program (no factorization) -- tiny E, extrapolate
    naive = operators.build_inverse_helmholtz(p, optimize=False)
    En = 4
    t_n = _time(
        lambda: naive.batched_fn({"S": S, "D": D[:En], "u": u[:En]})["v"],
        warmup=1, iters=2,
    )
    _row("tab2_ladder/naive_literal", t_n / En * E * 1e6,
         f"{flops / (t_n / En * E) / 1e9:.3f}GFLOPS")

    fact = operators.build_inverse_helmholtz(p)
    t1 = _time(
        lambda: [fact.batched_fn(
            {"S": S, "D": D[i:i + 1], "u": u[i:i + 1]})["v"]
            for i in range(32)],
        warmup=1, iters=2,
    )
    _row("tab2_ladder/serial_1elem", t1 / 32 * E * 1e6,
         f"{flops / (t1 / 32 * E) / 1e9:.3f}GFLOPS")

    t = _time(lambda: fact.batched_fn({"S": S, "D": D, "u": u})["v"])
    _row("tab2_ladder/factorized_xla", t * 1e6, f"{flops / t / 1e9:.3f}GFLOPS")

    for k in (1, 2, 3, 7):
        staged = operators.build_inverse_helmholtz(
            p, backend="staged", max_groups=k
        )
        tk = _time(lambda: staged.batched_fn({"S": S, "D": D, "u": u})["v"])
        _row(f"tab2_ladder/dataflow_{k}", tk * 1e6,
             f"{flops / tk / 1e9:.3f}GFLOPS")


def fig16_precision() -> None:
    rng = np.random.default_rng(1)
    for p in (7, 11):
        E = 256
        S, D, u = _helmholtz_data(p, E, rng, np.float64)
        flops = E * reference.paper_flops_per_element(p)
        oracle = reference.inverse_helmholtz_batch(S, D, u)
        for pol_name in ("float32", "bfloat16"):
            c = operators.build_inverse_helmholtz(p, policy=pol_name)
            env = {"S": S.astype(np.float32),
                   "D": D.astype(np.float32), "u": u.astype(np.float32)}
            try:
                t = _time(lambda: c.batched_fn(env)["v"])
                got = np.asarray(
                    c.batched_fn(env)["v"].astype(jnp.float32), np.float64
                )
            except Exception:
                # CPU runtime lacks BF16xBF16=F32 dot execution (the
                # bf16 policy is a TPU-target path; compile-only here)
                _row(f"fig16/{pol_name}_p{p}", 0.0,
                     "unsupported-on-cpu-runtime")
                continue
            mse = float(np.mean((got - oracle) ** 2))
            _row(f"fig16/{pol_name}_p{p}", t * 1e6,
                 f"{flops / t / 1e9:.3f}GFLOPS;mse={mse:.2e}")
        with enable_x64(True):
            for pol_name in ("fixed32_q8.24", "fixed64_q24.40"):
                pol = POLICIES[pol_name]
                c = operators.build_inverse_helmholtz(
                    p, policy=pol
                )
                env = {k: pol.encode(v) for k, v in
                       {"S": S, "D": D, "u": u}.items()}
                t = _time(lambda: c.batched_fn(env)["v"], warmup=1, iters=2)
                got = np.asarray(pol.decode(c.batched_fn(env)["v"]))
                mse = float(np.mean((got - oracle) ** 2))
                _row(f"fig16/{pol_name}_p{p}", t * 1e6,
                     f"{flops / t / 1e9:.3f}GOPS;mse={mse:.2e}")


def fig17_multicu() -> None:
    """CU replication / batching: elements per dispatch (the paper's E)
    and double-buffering on/off.  On this 1-core container replication
    cannot reduce wall time -- the paper's own conclusion when host
    bandwidth is the limit; the accounting structure is the deliverable."""
    for E in (256, 512, 1024):
        cfg = SimConfig(p=11, n_eq=4 * E, batch_elements=E)
        run_simulation(cfg, max_batches=2)  # warm
        res = run_simulation(cfg, max_batches=4)
        flops = res.elements * reference.paper_flops_per_element(11)
        _row(f"fig17/batch_{E}", res.wall_s / res.batches * 1e6,
             f"{flops / res.wall_s / 1e9:.3f}GFLOPS")
    for db in (False, True):
        cfg = SimConfig(p=11, n_eq=2048, batch_elements=512,
                        double_buffer=db)
        run_simulation(cfg, max_batches=2)
        res = run_simulation(cfg, max_batches=4)
        flops = res.elements * reference.paper_flops_per_element(11)
        _row(f"fig17/double_buffer_{db}", res.wall_s / res.batches * 1e6,
             f"{flops / res.wall_s / 1e9:.3f}GFLOPS")


def fig19_kernels() -> None:
    rng = np.random.default_rng(2)
    E = 512
    p = 11
    S, D, u = _helmholtz_data(p, E, rng)
    c = operators.build_inverse_helmholtz(p)
    t = _time(lambda: c.batched_fn({"S": S, "D": D, "u": u})["v"])
    fl = E * reference.paper_flops_per_element(p)
    _row("fig19/inverse_helmholtz", t * 1e6, f"{fl / t / 1e9:.3f}GFLOPS")

    n = m = 11
    A = rng.uniform(-1, 1, (m, n)).astype(np.float32)
    ui = rng.uniform(-1, 1, (E, n, n, n)).astype(np.float32)
    ci = operators.build_interpolation(n, m)
    ti = _time(lambda: ci.batched_fn({"A": A, "u": ui})["v"])
    fl_i = E * 2 * 3 * n ** 4
    _row("fig19/interpolation", ti * 1e6, f"{fl_i / ti / 1e9:.3f}GFLOPS")

    nx, ny, nz = 8, 7, 6
    Dx = rng.uniform(-1, 1, (nx, nx)).astype(np.float32)
    Dy = rng.uniform(-1, 1, (ny, ny)).astype(np.float32)
    Dz = rng.uniform(-1, 1, (nz, nz)).astype(np.float32)
    ug = rng.uniform(-1, 1, (E, nx, ny, nz)).astype(np.float32)
    cg = operators.build_gradient(nx, ny, nz)
    tg = _time(lambda: cg.batched_fn(
        {"Dx": Dx, "Dy": Dy, "Dz": Dz, "u": ug})["gx"])
    fl_g = E * 2 * (nx * nx * ny * nz + ny * ny * nx * nz + nz * nz * nx * ny)
    _row("fig19/gradient", tg * 1e6, f"{fl_g / tg / 1e9:.3f}GFLOPS")


def memplan_ladder() -> None:
    """The paper's baseline -> double-buffer -> dataflow ladder, but every
    rung generated from a MemoryPlan instead of hand-rolled driver knobs.
    Rows report measured us/batch plus the plan's predicted us/batch; the
    last row is the DSE winner for this machine."""
    from repro.memory import channels as mchan, dse

    target = mchan.detect_target()
    E, n_b = 512, 8
    n_eq = E * n_b
    rungs = [
        ("baseline", {"prefetch_depth": 0}),
        ("double_buffer", {"prefetch_depth": 1}),
        ("prefetch_4", {"prefetch_depth": 4}),
        ("dataflow", {"prefetch_depth": 1, "backend": "staged"}),
    ]
    for name, kw in rungs:
        plan = dse.make_plan(
            11, target=target, batch_elements=E, n_eq=n_eq, **kw
        )
        cfg = SimConfig(
            p=11, n_eq=n_eq, batch_elements=E,
            backend=kw.get("backend", "xla"),
            prefetch_depth=kw["prefetch_depth"],
        )
        run_simulation(cfg, plan=plan, max_batches=2)  # warm
        # min over repetitions: robust against CPU frequency/cache drift
        best = min(
            (run_simulation(cfg, plan=plan, max_batches=n_b)
             for _ in range(3)),
            key=lambda r: r.wall_s,
        )
        flops = best.elements * reference.paper_flops_per_element(11)
        _row(
            f"memplan_ladder/{name}", best.wall_s / best.batches * 1e6,
            f"{flops / best.wall_s / 1e9:.3f}GFLOPS;"
            f"pred={plan.cost.t_pipelined * 1e6:.0f}us",
        )
    # "this machine's winner": only CU counts that exist here, and report
    # the candidate that was actually measured (not just predicted)
    space = dse.DesignSpace(cu_counts=(jax.device_count(),))
    ranked = dse.explore(
        11, target=target, n_eq=n_eq, space=space, measure_top=1
    )
    best = next((c for c in ranked if c.verified), ranked[0])
    meas = best.measured_s_per_element
    _row(
        "memplan_ladder/dse_best",
        (meas if meas is not None else best.predicted_s_per_element)
        * best.plan.batch_elements * 1e6,
        f"backend={best.plan.backend};E={best.plan.batch_elements};"
        f"K={best.plan.prefetch_depth};CU={best.plan.cu_count};"
        f"{'measured' if meas is not None else 'predicted-only'};"
        f"pred={best.predicted_s_per_element * 1e6:.4f}us/elem",
    )


def _sharded_worker(p: int, E: int, n_b: int) -> None:
    """Subprocess body for the sharded rungs: runs the 3-stage chain
    under a 2-device placement (gradient stage element-sharded over both
    devices, handoffs resharded between groups) and prints one JSON line
    with the measurement.  Launched with
    ``--xla_force_host_platform_device_count=2`` by the parent ladder --
    the only way to exercise multi-device execution on a CPU container.
    """
    import json

    from repro.cfd.simulation import run_chain
    from repro.memory import chain as mchain
    from repro.memory import channels as mchan
    from repro.memory.placement import DeviceTopology

    assert jax.device_count() == 2, jax.devices()
    n_eq = E * n_b
    target = mchan.detect_target()
    chain = operators.build_cfd_chain(p)
    flops_pe = sum(s.program.total_flops() for s in chain.stages)
    rng = np.random.default_rng(7)
    inputs = {
        "interp.u": rng.uniform(-1, 1, (n_eq, p, p, p)).astype(np.float32),
        "helmholtz.D": rng.uniform(
            -1, 1, (n_eq, p, p, p)
        ).astype(np.float32),
    }
    shared = {
        name: rng.uniform(-1, 1, node.shape).astype(np.float32)
        for name, node in sorted(chain.shared_operands().items())
    }
    plan = mchain.plan_chain(
        chain, target=target, batch_elements=E, prefetch_depth=1,
        cu_count=(1, 2, 1), topology=DeviceTopology.homogeneous(2),
        n_eq=n_eq,
    )
    run_chain(chain, plan, inputs=inputs, shared=shared,
              max_batches=2)  # warm
    best = min(
        (run_chain(chain, plan, inputs=inputs, shared=shared,
                   n_eq=n_eq, max_batches=n_b)
         for _ in range(3)),
        key=lambda r: r.wall_s,
    )
    assert best.placement_groups is not None  # really ran multi-device
    print(json.dumps({
        "us_per_batch": best.wall_s / best.batches * 1e6,
        "gflops": best.elements * flops_pe / best.wall_s / 1e9,
        "groups": [list(g) for g in best.placement_groups],
        "host_stream_bytes": plan.host_stream_bytes,
        "pred_us": plan.cost.t_overlapped * 1e6,
    }))


def _hetero_worker(p: int, E: int, n_b: int) -> None:
    """Subprocess body for the heterogeneous rung: the same chain over a
    *declared* 2-kind topology (cpu-host + alveo-u280, one device each),
    stage 0 placed on the host group at half the chain E so the 0->1
    handoff crosses both an E change and a kind change and exercises the
    re-blocking path.  Both devices are really CPU host devices (forced
    by the parent), so the rung tracks the re-block machinery's wall
    cost, not a speedup -- and the declared-kind pricing is meaningless
    on this silicon, so no prediction fields are reported.
    """
    import json

    from repro.cfd.simulation import run_chain
    from repro.memory import chain as mchain
    from repro.memory import channels as mchan
    from repro.memory.placement import DeviceTopology

    assert jax.device_count() == 2, jax.devices()
    n_eq = E * n_b
    chain = operators.build_cfd_chain(p)
    flops_pe = sum(s.program.total_flops() for s in chain.stages)
    rng = np.random.default_rng(7)
    inputs = {
        "interp.u": rng.uniform(-1, 1, (n_eq, p, p, p)).astype(np.float32),
        "helmholtz.D": rng.uniform(
            -1, 1, (n_eq, p, p, p)
        ).astype(np.float32),
    }
    shared = {
        name: rng.uniform(-1, 1, node.shape).astype(np.float32)
        for name, node in sorted(chain.shared_operands().items())
    }
    plan = mchain.plan_chain(
        chain, target=mchan.ALVEO_U280, batch_elements=E,
        prefetch_depth=1,
        topology=DeviceTopology.parse("cpu:1,alveo:1"),
        stage_groups=(0, 1, 1), stage_batch_elements=(E // 2, E, E),
        n_eq=n_eq,
    )
    assert plan.cost.t_reblock and plan.cost.t_reblock[1] > 0
    run_chain(chain, plan, inputs=inputs, shared=shared,
              max_batches=2)  # warm
    best = min(
        (run_chain(chain, plan, inputs=inputs, shared=shared,
                   n_eq=n_eq, max_batches=n_b)
         for _ in range(3)),
        key=lambda r: r.wall_s,
    )
    assert best.placement_groups is not None  # really ran placed
    print(json.dumps({
        "us_per_batch": best.wall_s / best.batches * 1e6,
        "gflops": best.elements * flops_pe / best.wall_s / 1e9,
        "groups": [list(g) for g in best.placement_groups],
        "kinds": [plan.placement.stage_kind(i)
                  for i in range(len(plan.stages))],
        "stage_e": list(plan.stage_batch_elements),
    }))


def _run_sharded_rung(p: int, E: int, n_b: int,
                      worker: str = "_sharded_worker") -> dict:
    """Launch a forced-2-host-device worker subprocess (the only way to
    exercise multi-device placement on a CPU container)."""
    import json
    import os
    import subprocess

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, __file__, worker, str(p), str(E), str(n_b)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    if res.returncode != 0:
        raise RuntimeError(
            f"{worker} rung subprocess failed:\n{res.stderr[-3000:]}"
        )
    return json.loads(res.stdout.strip().splitlines()[-1])


def chain_ladder() -> None:
    """The full CFD application as one ProgramChain.  Rungs compare the
    unchained baseline (every stage streams through the host, as three
    standalone plans would) against the chain plan's HBM-resident
    execution, serial and prefetched.  Results also land in
    ``chain_ladder.json`` (override the path with $CHAIN_LADDER_JSON)."""
    import json
    import os

    from repro.cfd.simulation import run_chain
    from repro.memory import chain as mchain
    from repro.memory import channels as mchan, dse

    p, E, n_b = 7, 256, 6
    n_eq = E * n_b
    target = mchan.detect_target()
    chain = operators.build_cfd_chain(p)
    flops_pe = sum(s.program.total_flops() for s in chain.stages)
    rng = np.random.default_rng(7)
    inputs = {
        "interp.u": rng.uniform(-1, 1, (n_eq, p, p, p)).astype(np.float32),
        "helmholtz.D": rng.uniform(-1, 1, (n_eq, p, p, p)).astype(np.float32),
    }
    shared = {
        name: rng.uniform(-1, 1, node.shape).astype(np.float32)
        for name, node in sorted(chain.shared_operands().items())
    }
    rows = []

    def emit(name, us_per_batch, gflops, extra="", pred_s=None,
             profile_plan=None):
        _row(f"chain_ladder/{name}", us_per_batch,
             f"{gflops:.3f}GFLOPS{';' + extra if extra else ''}")
        row = {
            "name": name, "us_per_batch": us_per_batch,
            "gflops": gflops, "extra": extra,
        }
        if pred_s is not None:
            row.update(_pred_fields(pred_s, us_per_batch, E))
        rows.append(row)
        if profile_plan is not None and pred_s is not None:
            _profile_record(profile_plan, pred_s, us_per_batch,
                            f"chain_ladder/{name}")

    # unchained baseline: each stage a separate dispatch with a host
    # round-trip between (what three standalone MemoryPlans execute)
    interp, grad, helm = (s.compiled for s in chain.stages)

    def unchained_batch(b):
        sl = slice(b * E, (b + 1) * E)
        w = np.asarray(interp.batched_fn(
            {"A": shared["A"], "u": inputs["interp.u"][sl]})["w"])
        g = grad.batched_fn({
            "Dx": shared["Dx"], "Dy": shared["Dy"], "Dz": shared["Dz"],
            "w": np.asarray(w),
        })
        gx = np.asarray(g["gx"])
        out = helm.batched_fn({
            "S": shared["S"], "D": inputs["helmholtz.D"][sl], "gx": gx,
        })
        return float(jnp.sum(out["v"]))

    unchained_batch(0)  # warm compile
    t0 = time.perf_counter()
    for b in range(n_b):
        unchained_batch(b)
    t_unchained = (time.perf_counter() - t0) / n_b
    emit("unchained_host_roundtrip", t_unchained * 1e6,
         E * flops_pe / t_unchained / 1e9)

    # rungs 2-4 run back-to-back (pipeline_stages=False) so the ladder
    # isolates staging depth; the last rung turns on cross-batch stage
    # pipelining (one dispatch ring per stage) at the same K=1
    rungs = (
        ("chained_serial", 0, False),
        ("chained_double_buffer", 1, False),
        ("chained_prefetch_2", 2, False),
        ("chained_stage_pipelined", 1, True),
    )
    for name, depth, piped in rungs:
        plan = mchain.plan_chain(
            chain, target=target, batch_elements=E,
            prefetch_depth=depth, n_eq=n_eq,
        )
        run_chain(chain, plan, inputs=inputs, shared=shared,
                  max_batches=2, pipeline_stages=piped)  # warm
        best = min(
            (run_chain(chain, plan, inputs=inputs, shared=shared,
                       n_eq=n_eq, max_batches=n_b, pipeline_stages=piped)
             for _ in range(3)),
            key=lambda r: r.wall_s,
        )
        pred = (
            plan.cost.t_overlapped if piped else plan.cost.t_back_to_back
        )
        emit(name, best.wall_s / best.batches * 1e6,
             best.elements * flops_pe / best.wall_s / 1e9,
             f"pred={pred * 1e6:.0f}us", pred_s=pred, profile_plan=plan)

    # sharded rung: the same chain under a 2-device placement (gradient
    # stage element-sharded, handoffs resharded between groups), run in
    # a subprocess with a forced host device count.  On this container
    # both "devices" share one CPU, so the rung tracks the placement
    # machinery's overhead rather than a speedup.
    sh = _run_sharded_rung(p, E, n_b)
    emit("chained_sharded_2dev", sh["us_per_batch"], sh["gflops"],
         f"groups={sh['groups']};pred={sh['pred_us']:.0f}us",
         pred_s=sh["pred_us"] * 1e-6)

    # heterogeneous rung: the same chain over a declared 2-kind topology
    # (cpu-host + alveo-u280) with the host stage re-blocked to E/2, so
    # every batch pays a real re-blocking handoff.  No prediction fields
    # -- the declared-kind pricing does not describe this CPU container.
    # The checked-in baseline caps this rung at max_ratio_vs the
    # homogeneous sharded rung: re-blocking must stay within 1.5x of the
    # plain 2-device placement, machine-independently.
    het = _run_sharded_rung(p, E, n_b, worker="_hetero_worker")
    emit("chained_hetero_2kind", het["us_per_batch"], het["gflops"],
         f"groups={het['groups']};kinds={','.join(het['kinds'])};"
         f"stage_e={het['stage_e']}")
    rows[-1].update(
        {"max_ratio_vs": "chained_sharded_2dev", "max_ratio": 1.5}
    )

    # the residency claim, in bytes: chain host streams vs the sum of
    # three standalone plans at the same E
    plan = mchain.plan_chain(
        chain, target=target, batch_elements=E, prefetch_depth=1,
        n_eq=n_eq,
    )
    standalone = sum(
        dse.make_plan(
            s.program, target=target, batch_elements=E,
            operator_name=s.name,
        ).host_stream_bytes
        for s in chain.stages
    )
    # not a timing row: keep the us_per_call column honest (0.0) and put
    # the byte accounting in the derived field + the JSON artifact
    _row("chain_ladder/host_stream_residency", 0.0,
         f"chain_bytes_per_batch={plan.host_stream_bytes};"
         f"standalone_sum={standalone};"
         f"saved={1 - plan.host_stream_bytes / standalone:.1%}")

    path = os.environ.get("CHAIN_LADDER_JSON", "chain_ladder.json")
    with open(path, "w") as f:
        json.dump({
            "p": p, "E": E, "n_batches": n_b,
            "target": target.name,
            "rows": rows,
            "host_stream_bytes": {
                "chain": plan.host_stream_bytes,
                "standalone_sum": standalone,
            },
        }, f, indent=2)


def flow_ladder() -> None:
    """The tool-flow acceptance ladder: the same CFD pipeline compiled
    (a) by hand-granularity stage cuts (``operators.build_cfd_chain``)
    and (b) fully automatically from source by ``repro.flow`` (stages
    derived from the scheduler's dataflow groups), plus the cross-batch
    stage-pipelining acceptance pair on the 3-stage chain (serial
    back-to-back vs one dispatch ring per stage; the checked-in
    baseline records the speedup and CI's regression gate enforces its
    floor).  Rows report measured us/batch; results land in
    ``flow_ladder.json`` (override the path with $FLOW_LADDER_JSON)."""
    import json
    import os

    from repro import flow
    from repro.cfd.simulation import run_chain
    from repro.memory import chain as mchain
    from repro.memory import channels as mchan

    p, E, n_b = 7, 256, 6
    n_eq = E * n_b
    target = mchan.detect_target()
    rng = np.random.default_rng(11)
    source = operators.CFD_PIPELINE_SRC.format(p=p)
    shared_arrays = {
        name: rng.uniform(-1, 1, (p, p)).astype(np.float32)
        for name in ("A", "Dx", "Dy", "Dz", "S")
    }
    rows = []

    def measure(name, chain, plan, *, E, n_b, pipeline_stages=None,
                reps=3):
        n = E * n_b
        inputs = {}
        data = {
            "u": rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32),
            "D": rng.uniform(-1, 1, (n, p, p, p)).astype(np.float32),
        }
        for i, s in enumerate(chain.stages):
            for in_name, _ in chain.host_element_inputs(i):
                inputs[f"{s.name}.{in_name}"] = data[in_name]
        flops_pe = sum(s.program.total_flops() for s in chain.stages)
        run_chain(chain, plan, inputs=inputs, shared=shared_arrays,
                  max_batches=2, pipeline_stages=pipeline_stages)  # warm
        best = min(
            (run_chain(chain, plan, inputs=inputs, shared=shared_arrays,
                       n_eq=n, max_batches=n_b,
                       pipeline_stages=pipeline_stages)
             for _ in range(reps)),
            key=lambda r: r.wall_s,
        )
        us = best.wall_s / best.batches * 1e6
        gflops = best.elements * flops_pe / best.wall_s / 1e9
        _row(f"flow_ladder/{name}", us,
             f"{gflops:.3f}GFLOPS;stages={len(chain.stages)};"
             f"pred={plan.cost.t_pipelined * 1e6:.0f}us")
        # predicted per-batch for the schedule actually run (the plan's
        # own mode unless measure() forced one)
        pred = (
            plan.cost.t_pipelined if pipeline_stages is None
            else plan.cost.t_overlapped if pipeline_stages
            else plan.cost.t_back_to_back
        )
        rows.append({
            "name": name, "us_per_batch": us, "gflops": gflops,
            "stages": len(chain.stages),
            "host_stream_bytes": plan.host_stream_bytes,
            **_pred_fields(pred, us, E),
        })
        _profile_record(plan, pred, us, f"flow_ladder/{name}")
        return us

    hand = operators.build_cfd_chain(p)
    hand_plan = mchain.plan_chain(
        hand, target=target, batch_elements=E, prefetch_depth=1, n_eq=n_eq
    )
    measure("hand_stage_cuts", hand, hand_plan, E=E, n_b=n_b)

    auto = flow.compile(
        source, name=f"cfd_pipeline_p{p}", target=target,
        batch_elements=E, prefetch_depth=1, n_eq=n_eq,
    )
    measure("flow_auto_stages", auto.chain, auto.plan, E=E, n_b=n_b)

    # cost-driven fusion: the stage count made a design axis.  The
    # max_stages=3 budget asks for the paper's 3-module granularity and
    # lets the greedy pass keep erasing boundaries while the planner
    # prices the HBM handoff above the fused roofline.  The checked-in
    # baseline carries max_ratio_vs=hand_stage_cuts: CI requires the
    # auto-fused pipeline to stay within 1.2x of the hand cuts -- a
    # same-machine ratio, so it holds across runner generations.
    fused = flow.compile(
        source, name=f"cfd_pipeline_p{p}", target=target,
        batch_elements=E, prefetch_depth=1, n_eq=n_eq, fuse="auto",
        max_stages=3,
    )
    fspec = fused.plan.fusion
    measure("chain_auto_fused", fused.chain, fused.plan, E=E, n_b=n_b)
    rows[-1].update({"max_ratio_vs": "hand_stage_cuts", "max_ratio": 1.2})

    # the same fused pipeline dispatched to the tiled GEMM-chain Pallas
    # kernel class (on this CPU container the class's XLA reference path
    # runs; the kernel itself is gated by interpret-mode unit tests)
    tiled = flow.compile(
        source, name=f"cfd_pipeline_p{p}", target=target,
        batch_elements=E, prefetch_depth=1, n_eq=n_eq, fuse="auto",
        max_stages=3, backend="pallas",
    )
    measure("gemm_tiled", tiled.chain, tiled.plan, E=E, n_b=n_b)

    # the stage-pipelining acceptance ladder: small batches on the
    # 3-stage chain so per-batch dispatch/sync latency -- exactly what
    # staging and the skewed dispatch rings hide -- dominates.  Three
    # rungs decompose the win: K=0 sync-per-batch (the paper's serial
    # baseline), the same K=1 plan run back-to-back (staging only), and
    # the K=1 plan stage-pipelined, so the gated speedup (pipelined vs
    # serial) and the executor's own contribution (vs back-to-back) are
    # both recorded; the skew *semantics* are guarded functionally by
    # the dispatch-order and bitwise tests in tests/test_memory.py.
    sp_E, sp_n_b = 64, 16
    serial_plan = mchain.plan_chain(
        hand, target=target, batch_elements=sp_E, prefetch_depth=0,
        n_eq=sp_E * sp_n_b,
    )
    piped_plan = mchain.plan_chain(
        hand, target=target, batch_elements=sp_E, prefetch_depth=1,
        n_eq=sp_E * sp_n_b,
    )
    us_serial = measure(
        "chain3_serial_stages", hand, serial_plan, E=sp_E, n_b=sp_n_b,
        pipeline_stages=False, reps=5,
    )
    us_b2b = measure(
        "chain3_back_to_back", hand, piped_plan, E=sp_E, n_b=sp_n_b,
        pipeline_stages=False, reps=5,
    )
    us_piped = measure(
        "chain3_stage_pipelined", hand, piped_plan, E=sp_E, n_b=sp_n_b,
        pipeline_stages=True, reps=5,
    )
    # sharded acceptance rung: same E/n_b as the chain3 pair, gradient
    # stage sharded over a 2-device placement in a subprocess
    sh = _run_sharded_rung(p, sp_E, sp_n_b)
    _row("flow_ladder/chain3_sharded_2dev", sh["us_per_batch"],
         f"{sh['gflops']:.3f}GFLOPS;groups={sh['groups']}")
    rows.append({
        "name": "chain3_sharded_2dev",
        "us_per_batch": sh["us_per_batch"], "gflops": sh["gflops"],
        "stages": 3, "host_stream_bytes": sh["host_stream_bytes"],
        **_pred_fields(sh["pred_us"] * 1e-6, sh["us_per_batch"], sp_E),
    })

    speedup = us_serial / us_piped if us_piped else 0.0
    stage_ratio = us_b2b / us_piped if us_piped else 0.0
    _row("flow_ladder/stage_pipelining_speedup", 0.0,
         f"speedup={speedup:.2f}x;serial={us_serial:.0f}us;"
         f"back_to_back={us_b2b:.0f}us;pipelined={us_piped:.0f}us;"
         f"stage_ratio={stage_ratio:.2f}x;"
         f"pred={piped_plan.cost.stage_overlap_speedup:.2f}x")

    path = os.environ.get("FLOW_LADDER_JSON", "flow_ladder.json")
    with open(path, "w") as f:
        json.dump({
            "p": p, "E": E, "n_batches": n_b, "target": target.name,
            "rows": rows,
            "fusion": {
                "groups": [list(g) for g in fspec.groups],
                "n_stages_before": fspec.n_stages_before,
                "n_stages_after": fspec.n_stages_after,
                "t_unfused_s": fspec.t_unfused,
                "t_fused_s": fspec.t_fused,
                "saved_handoff_bytes": fspec.saved_handoff_bytes,
            },
            "stage_pipelining": {
                "E": sp_E, "n_batches": sp_n_b,
                "serial_us_per_batch": us_serial,
                "back_to_back_us_per_batch": us_b2b,
                "pipelined_us_per_batch": us_piped,
                "speedup": speedup,
                "stage_ratio": stage_ratio,
                # the acceptance floor CI's gate enforces (ratio of two
                # same-machine runs: robust across runner generations).
                # 1.0 = pipelining must never lose to the serial
                # schedule; the absolute win depends on the host's
                # dispatch/sync latency (2x on slow-dispatch runners,
                # near-parity when sync is cheap), so a higher floor
                # would gate on the runner, not the executor.
                "min_speedup": 1.0,
                # the executor's own floor: stage-pipelined execution of
                # the same plan must not fall behind back-to-back by
                # more than measurement noise
                "min_stage_ratio": 0.9,
            },
        }, f, indent=2)


def lm_throughput() -> None:
    import repro.configs as configs
    from repro.models import build_model
    from repro.optim import AdamWConfig
    from repro.runtime.train import init_train_state, make_train_step

    cfg = configs.get_smoke("qwen3-14b")
    model = build_model(cfg, attn_impl="xla")
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, AdamWConfig()))
    B, T = 8, 128
    batch = {
        "tokens": jnp.ones((B, T), jnp.int32),
        "labels": jnp.ones((B, T), jnp.int32),
    }

    def one():
        nonlocal state
        state, m = step(state, batch)
        return m["loss"]

    t = _time(one, warmup=2, iters=5)
    _row("lm/train_step_smoke", t * 1e6, f"{B * T / t:.0f}tok/s")

    cache = model.init_cache(B, 256)
    logits, cache = jax.jit(model.prefill)(
        state["params"], {"tokens": batch["tokens"]}, cache
    )
    dstep = jax.jit(model.decode_step)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    def dec():
        return dstep(state["params"], tok, cache, jnp.int32(T))

    td = _time(dec, warmup=2, iters=10)
    _row("lm/decode_step_smoke", td * 1e6, f"{B / td:.0f}tok/s")


BENCHES = {
    "tab2_ladder": tab2_ladder,
    "fig16_precision": fig16_precision,
    "fig17_multicu": fig17_multicu,
    "fig19_kernels": fig19_kernels,
    "memplan_ladder": memplan_ladder,
    "chain_ladder": chain_ladder,
    "flow_ladder": flow_ladder,
    "lm_throughput": lm_throughput,
}


def main() -> None:
    workers = {
        "_sharded_worker": _sharded_worker,
        "_hetero_worker": _hetero_worker,
    }
    if len(sys.argv) > 1 and sys.argv[1] in workers:
        workers[sys.argv[1]](
            int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
        )
        return
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == "__main__":
    main()
