"""Benchmark regression gate: compare a fresh ladder JSON against its
checked-in baseline (``benchmarks/BENCH_<name>.json``).

Usage::

    python benchmarks/compare.py benchmarks/BENCH_chain_ladder.json \
        chain_ladder.json

Checks, in order of strength:

  * **speedup floor** (machine-independent): when the baseline records a
    ``stage_pipelining`` section, the current run's serial/pipelined
    speedup must reach the baseline's ``min_speedup`` -- a ratio of two
    runs on the *same* machine, so it holds across runner generations.
  * **residency bytes** (deterministic): planner-derived byte counts
    (``host_stream_bytes``) must not grow -- a regression here is a real
    planner change, not noise.
  * **rung ratio caps** (machine-independent): a baseline row carrying
    ``max_ratio_vs``/``max_ratio`` pins the current run's us/batch to at
    most ``max_ratio`` times another current rung's (e.g. the auto-fused
    pipeline must stay within 1.2x of the hand stage cuts) -- again a
    ratio of two same-machine measurements.
  * **us/batch per row** (noisy): a row regresses when its measured
    us/batch exceeds baseline * (1 + threshold).  The threshold is
    env-tunable (``BENCH_REGRESSION_THRESHOLD``, default 1.0 = allow up
    to 2x) because CI wall clocks drift wildly; ratios above do the
    precise policing.
  * **prediction error** (model health): rows carrying a
    ``prediction_error`` field (``max(pred/meas, meas/pred)`` from the
    planner's cost model vs the measured run) must stay under
    ``BENCH_PRED_ERROR_MAX`` (default 25 -- generous, since shared CI
    runners stall by an order of magnitude; tighten locally to audit
    the cost model).
  * **row coverage**: every baseline row must still exist (a silently
    dropped rung is a regression in what we measure).

Escape hatches: ``BENCH_SKIP=1`` exits 0 immediately (CI wires this to
the ``skip-bench-gate`` PR label).  The comparison table is printed and,
when ``$GITHUB_STEP_SUMMARY`` is set, appended there as markdown.

Exit codes: 0 pass/skip, 1 regression, 2 usage error.
"""
from __future__ import annotations

import json
import os
import sys
from typing import List, Optional, Tuple


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _rows_by_name(doc: dict) -> dict:
    return {r["name"]: r for r in doc.get("rows", [])}


def compare(
    baseline: dict, current: dict, *, threshold: float,
    pred_error_max: float = 25.0,
) -> Tuple[List[str], List[Tuple[str, float, float, str]]]:
    """Returns (failures, table rows).  Table rows are
    (name, baseline_us, current_us, verdict)."""
    failures: List[str] = []
    table: List[Tuple[str, float, float, str]] = []

    base_rows = _rows_by_name(baseline)
    cur_rows = _rows_by_name(current)
    for name, base in base_rows.items():
        cur = cur_rows.get(name)
        if cur is None:
            failures.append(f"row {name!r} missing from current run")
            table.append((name, base["us_per_batch"], float("nan"),
                          "MISSING"))
            continue
        b_us, c_us = base["us_per_batch"], cur["us_per_batch"]
        if b_us > 0:
            limit = b_us * (1.0 + threshold)
            if c_us > limit:
                failures.append(
                    f"{name}: {c_us:.1f} us/batch exceeds baseline "
                    f"{b_us:.1f} us/batch by more than the "
                    f"{threshold:.0%} noise threshold"
                )
                table.append((name, b_us, c_us, "REGRESSED"))
            else:
                table.append((name, b_us, c_us, "ok"))
        else:
            table.append((name, b_us, c_us, "ok (untimed)"))
        # deterministic planner outputs piggybacking on timing rows
        b_bytes = base.get("host_stream_bytes")
        c_bytes = cur.get("host_stream_bytes")
        if b_bytes is not None and c_bytes is not None and c_bytes > b_bytes:
            failures.append(
                f"{name}: host_stream_bytes grew {b_bytes} -> {c_bytes} "
                "(planner residency regression; deterministic, not noise)"
            )
        # cost-model health: the planner's prediction must stay within
        # a (generous) multiplicative band of what actually ran
        pe = cur.get("prediction_error")
        if pe is not None and pred_error_max > 0 and pe > pred_error_max:
            failures.append(
                f"{name}: prediction_error {pe:.1f}x exceeds "
                f"BENCH_PRED_ERROR_MAX={pred_error_max:g} (cost model "
                f"predicted {cur.get('predicted_s_per_element', 0) * 1e6:.3f} "
                f"us/elem, measured "
                f"{cur.get('measured_s_per_element', 0) * 1e6:.3f} us/elem)"
            )
        # rung ratio cap: both sides measured in the *current* run, so
        # the check is machine-independent (e.g. auto-fused vs hand cuts)
        ref_name = base.get("max_ratio_vs")
        cap = base.get("max_ratio")
        if ref_name and cap:
            ref = cur_rows.get(ref_name)
            if ref is None:
                failures.append(
                    f"{name}: ratio reference rung {ref_name!r} missing "
                    "from current run"
                )
            elif ref["us_per_batch"] > 0:
                ratio = c_us / ref["us_per_batch"]
                if ratio > cap:
                    failures.append(
                        f"{name}: {c_us:.1f} us/batch is {ratio:.2f}x "
                        f"of {ref_name} ({ref['us_per_batch']:.1f} "
                        f"us/batch), above the {cap:g}x cap"
                    )
    for name in cur_rows.keys() - base_rows.keys():
        table.append((name, float("nan"), cur_rows[name]["us_per_batch"],
                      "new (no baseline)"))

    sp_base = baseline.get("stage_pipelining")
    sp_cur = current.get("stage_pipelining")
    if sp_base:
        if sp_cur is None:
            failures.append("stage_pipelining section missing from "
                            "current run")
        else:
            floor = sp_base.get("min_speedup")
            if floor is not None and sp_cur["speedup"] < floor:
                failures.append(
                    f"stage-pipelining speedup {sp_cur['speedup']:.2f}x "
                    f"fell below the baseline floor {floor:.2f}x "
                    f"(baseline measured {sp_base['speedup']:.2f}x)"
                )
            ratio_floor = sp_base.get("min_stage_ratio")
            ratio = sp_cur.get("stage_ratio")
            if ratio_floor is not None and ratio is not None \
                    and ratio < ratio_floor:
                failures.append(
                    f"stage-pipelined execution fell to {ratio:.2f}x of "
                    f"the same plan run back-to-back (floor "
                    f"{ratio_floor:.2f}x; baseline measured "
                    f"{sp_base.get('stage_ratio', 0):.2f}x) -- the "
                    "executor itself regressed"
                )
    hs_base = baseline.get("host_stream_bytes")
    hs_cur = current.get("host_stream_bytes")
    if (isinstance(hs_base, dict) and isinstance(hs_cur, dict)
            and hs_cur.get("chain", 0) > hs_base.get("chain", 0)):
        failures.append(
            f"chain host_stream_bytes grew {hs_base['chain']} -> "
            f"{hs_cur['chain']} (planner residency regression)"
        )
    return failures, table


def render_markdown(
    name: str,
    table: List[Tuple[str, float, float, str]],
    failures: List[str],
    current: dict,
) -> str:
    lines = [
        f"### benchmark gate: {name} "
        f"{'FAILED' if failures else 'passed'}",
        "",
        "| rung | baseline us/batch | current us/batch | verdict |",
        "| --- | ---: | ---: | --- |",
    ]
    for row, b, c, verdict in table:
        fmt = lambda v: "-" if v != v else f"{v:.1f}"  # NaN-safe
        lines.append(f"| {row} | {fmt(b)} | {fmt(c)} | {verdict} |")
    sp = current.get("stage_pipelining")
    if sp:
        b2b = sp.get("back_to_back_us_per_batch")
        lines += [
            "",
            f"stage-pipelining speedup: **{sp['speedup']:.2f}x** "
            f"(serial {sp['serial_us_per_batch']:.0f} us/batch"
            + (f", back-to-back {b2b:.0f} us/batch" if b2b else "")
            + f", pipelined {sp['pipelined_us_per_batch']:.0f} us/batch; "
            f"floor {sp.get('min_speedup', '-')}x)",
        ]
    if failures:
        lines += [""] + [f"- :x: {f}" for f in failures]
    return "\n".join(lines) + "\n"


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if os.environ.get("BENCH_SKIP"):
        print("BENCH_SKIP set: benchmark regression gate skipped")
        return 0
    if len(argv) != 2:
        print(__doc__, file=sys.stderr)
        return 2
    baseline_path, current_path = argv
    try:
        baseline, current = _load(baseline_path), _load(current_path)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    threshold = float(os.environ.get("BENCH_REGRESSION_THRESHOLD", "1.0"))
    pred_error_max = float(os.environ.get("BENCH_PRED_ERROR_MAX", "25"))
    failures, table = compare(
        baseline, current, threshold=threshold,
        pred_error_max=pred_error_max,
    )

    name = os.path.basename(baseline_path)
    md = render_markdown(name, table, failures, current)
    print(md)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(md + "\n")
    if failures:
        print(f"{len(failures)} regression(s) vs {baseline_path}; "
              "re-run, raise BENCH_REGRESSION_THRESHOLD, or apply the "
              "skip-bench-gate label if expected", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
